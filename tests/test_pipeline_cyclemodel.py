"""Dobu schedule invariants + Snitch/TPU cycle-model validation.

The Snitch model is the paper-faithful instrument: it must hit the
published Table II anchors and reproduce the Fig. 5 ordering of the
five cluster configurations (EXPERIMENTS.md carries the full numbers).
"""

import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core.cyclemodel import (SNITCH_CONFIGS, SnitchClusterModel,
                                   TpuPipelineModel)
from repro.core.pipeline import DobuSchedule


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 200), st.integers(2, 4))
def test_dobu_schedule_conflict_free(steps, slots):
    """The hyperbank invariant: producer slot != consumer slot, ever."""
    s = DobuSchedule(steps=steps, slots=slots)
    assert s.conflict_free()
    phases = list(s.phases())
    assert len(phases) == steps
    # every step's operands were prefetched into the slot it consumes
    for ph in phases[:-1]:
        assert ph.prefetch_step == ph.step + 1
        assert ph.prefetch_slot == s.slot_of(ph.step + 1)


def test_dobu_needs_two_slots():
    with pytest.raises(ValueError):
        DobuSchedule(steps=4, slots=1)


# ----------------------------------------------------------------------
# Snitch cluster model vs published anchors
# ----------------------------------------------------------------------
def test_table2_anchors():
    base = SnitchClusterModel(SNITCH_CONFIGS["base32fc"]).matmul(
        32, 32, 32, include_dma=False)
    ours = SnitchClusterModel(SNITCH_CONFIGS["zonl48dobu"]).matmul(
        32, 32, 32, include_dma=False)
    assert abs(base.utilization - 0.953) < 0.005   # paper: 95.3%
    assert abs(ours.utilization - 0.990) < 0.005   # paper: 99.0%
    assert abs(base.perf_gflops - 7.63) < 0.05     # paper: 7.63
    assert abs(ours.perf_gflops - 7.92) < 0.05     # paper: 7.92


def _fig5_sizes(n=50, seed=42):
    rng = np.random.default_rng(seed)
    space = list(range(8, 136, 8))
    return [(int(rng.choice(space)), int(rng.choice(space)),
             int(rng.choice(space))) for _ in range(n)]


def test_fig5_ordering_and_medians():
    meds = {}
    for name, cfg in SNITCH_CONFIGS.items():
        m = SnitchClusterModel(cfg)
        meds[name] = float(np.median(
            [m.matmul(*s).utilization for s in _fig5_sizes()]))
    # paper medians: 88.2 / 93.4 / 98.1 / ~98 / ~98-99
    assert abs(meds["base32fc"] - 0.882) < 0.02
    assert abs(meds["zonl32fc"] - 0.934) < 0.02
    assert abs(meds["zonl64fc"] - 0.981) < 0.02
    # strict ordering of the paper's progression
    assert meds["base32fc"] < meds["zonl32fc"] < meds["zonl64fc"]
    assert meds["zonl64dobu"] == pytest.approx(meds["zonl64fc"], abs=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.sampled_from(list(range(8, 136, 8))),
       st.sampled_from(list(range(8, 136, 8))),
       st.sampled_from(list(range(8, 136, 8))))
def test_zonl_dominates_baseline_everywhere(m, n, k):
    """ZONL can never hurt: per-size utilization is >= baseline's."""
    base = SnitchClusterModel(SNITCH_CONFIGS["base32fc"]).matmul(m, n, k)
    zonl = SnitchClusterModel(SNITCH_CONFIGS["zonl32fc"]).matmul(m, n, k)
    dobu = SnitchClusterModel(SNITCH_CONFIGS["zonl48dobu"]).matmul(m, n, k)
    assert zonl.utilization >= base.utilization
    assert dobu.utilization >= zonl.utilization
    assert dobu.stall_cycles_conflict == 0      # zero-conflict by design
    assert dobu.overhead_cycles_loop == 0       # zero-overhead by design


def test_energy_efficiency_improvement():
    """Paper: zonl48dobu improves median energy efficiency ~8% vs base."""
    sizes = _fig5_sizes()
    base = SnitchClusterModel(SNITCH_CONFIGS["base32fc"])
    ours = SnitchClusterModel(SNITCH_CONFIGS["zonl48dobu"])
    eff_b = np.median([base.matmul(*s).energy_eff_gflops_w for s in sizes])
    eff_o = np.median([ours.matmul(*s).energy_eff_gflops_w for s in sizes])
    gain = eff_o / eff_b - 1
    assert 0.04 < gain < 0.12   # paper: +8%


# ----------------------------------------------------------------------
# TPU pipeline model
# ----------------------------------------------------------------------
def test_tpu_double_buffering_wins():
    m = TpuPipelineModel()
    db = m.matmul(4096, 4096, 4096, 512, 512, 512, double_buffered=True)
    sb = m.matmul(4096, 4096, 4096, 512, 512, 512, double_buffered=False)
    assert db.total_s < sb.total_s
    assert db.mxu_utilization > sb.mxu_utilization
    assert 0 < db.mxu_utilization <= 1.0


def test_tpu_grid_vs_host_loop():
    m = TpuPipelineModel()
    grid = m.matmul(2048, 2048, 2048, 256, 256, 256, grid_loop=True)
    host = m.matmul(2048, 2048, 2048, 256, 256, 256, grid_loop=False)
    assert grid.total_s < host.total_s      # ZONL analogue wins
    assert host.overhead_s > 0 and grid.overhead_s == 0


def test_vmem_footprint_fits():
    m = TpuPipelineModel()
    assert m.vmem_footprint(512, 512, 512) < m.p.vmem_bytes
