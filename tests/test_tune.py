"""repro.tune: space pruning, oracle ordering, cache, kernel N-slot parity.

Covers the autotuning acceptance criteria:
  * cache round-trip (save/load/hit) against a tmp path;
  * VMEM-feasibility pruning (oversized tiles never enumerated);
  * the analytic oracle prefers dobu over single and deeper-ring
    configs never lose to the serialized baseline;
  * N-slot kernel correctness vs ref.matmul_ref under interpret=True
    for slots in (1, 2, 3);
  * auto-plan ops.matmul (config=Plan(backend=...)) is bit-identical
    to the default path and the second resolution of the same shape
    is a cache hit.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import tune
from repro.core.cyclemodel import TpuPipelineModel
from repro.core.pipeline import DobuSchedule, RevolvingSchedule
from repro.kernels import ops, ref
from repro.plan import KernelConfig, Plan
from repro.kernels.grouped_matmul import grouped_zero_stall_matmul
from repro.kernels.zero_stall_matmul import zero_stall_matmul
from repro.tune import (AnalyticOracle, Candidate, KernelSpace, Problem,
                        TuneCache)


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Point the process-wide tuner cache at a fresh tmp file."""
    cache = TuneCache(tmp_path / "tune.json")
    tune.set_cache(cache)
    yield cache
    tune.set_cache(None)


# ----------------------------------------------------------------------
# KernelSpace: legality + VMEM pruning
# ----------------------------------------------------------------------
def test_space_prunes_vmem_infeasible():
    space = KernelSpace(tile_options=(128, 512, 2048),
                        slot_options=(1, 2, 3, 4))
    p = Problem("matmul", 8192, 8192, 8192, dtype_bytes=2)
    model = TpuPipelineModel()
    cands = list(space.candidates(p))
    assert cands, "space must not be empty"
    for c in cands:
        fp = model.vmem_footprint(c.bm, c.bn, c.bk, dtype_bytes=2,
                                  slots=c.slots)
        assert fp <= space.vmem_budget
    # 2048³ x 4 slots = 80 MiB > the 64 MiB budget: pruned
    assert not space.feasible(Candidate(2048, 2048, 2048, slots=4), p)
    assert Candidate(2048, 2048, 2048, 4) not in cands
    # ... but the same tiles fit at depth 2 (48 MiB): kept
    assert space.feasible(Candidate(2048, 2048, 2048, slots=2), p)


def test_space_rejects_misaligned_and_oversized():
    space = KernelSpace(tile_options=(128, 256), align=128)
    p = Problem("matmul", 256, 256, 256)
    assert not space.feasible(Candidate(100, 128, 128), p)   # misaligned
    assert space.feasible(Candidate(256, 256, 256), p)
    # tile beyond the padded problem = pure zero-padding work
    assert not space.feasible(Candidate(256, 256, 256),
                              Problem("matmul", 64, 64, 64))


def test_space_candidates_deterministic():
    space = tune.INTERPRET_SPACE
    p = Problem("matmul", 48, 32, 40, dtype_bytes=4)
    assert list(space.candidates(p)) == list(space.candidates(p))


# ----------------------------------------------------------------------
# Analytic oracle: paper-consistent preferences
# ----------------------------------------------------------------------
def test_oracle_picks_dobu_over_single():
    o = AnalyticOracle()
    for (M, N, K, db) in [(2048, 2048, 2048, 2), (4096, 11008, 4096, 2),
                          (128, 128, 8192, 2), (48, 32, 40, 4)]:
        p = Problem("matmul", M, N, K, dtype_bytes=db)
        for tiles in ((128, 128, 128), (256, 256, 256)):
            single = o.estimate(Candidate(*tiles, 1), p)
            dobu = o.estimate(Candidate(*tiles, 2), p)
            assert dobu <= single
    # and strictly better on a many-step problem
    p = Problem("matmul", 4096, 4096, 4096)
    assert (o.estimate(Candidate(128, 128, 128, 2), p)
            < o.estimate(Candidate(128, 128, 128, 1), p))


def test_autotune_never_returns_single_when_dobu_fits():
    for backend in ("pallas", "interpret"):
        c = tune.best_config("matmul", 1024, 1024, 1024,
                             dtype=jnp.bfloat16, backend=backend,
                             cache=TuneCache("/dev/null/nope"))
        assert c.slots >= 2, c


def test_oracle_rejected_tiles_exceeding_vmem_never_win(tmp_cache):
    """End-to-end: the tuned config always fits the VMEM budget."""
    space = tune.DEFAULT_SPACE
    model = TpuPipelineModel()
    c = tune.best_config("matmul", 16384, 16384, 16384,
                         dtype=jnp.bfloat16, backend="pallas")
    fp = model.vmem_footprint(c.bm, c.bn, c.bk, dtype_bytes=2, slots=c.slots)
    assert fp <= space.vmem_budget


# ----------------------------------------------------------------------
# Cache: round-trip + hit accounting
# ----------------------------------------------------------------------
def test_cache_round_trip(tmp_path):
    path = tmp_path / "tune.json"
    c1 = TuneCache(path)
    p = Problem("matmul", 4096, 11008, 4096)
    key = TuneCache.key(p, backend="pallas", dtype="bfloat16")
    assert c1.get(key) is None and c1.misses == 1
    cand = Candidate(256, 512, 128, slots=3, grid_order="jik")
    c1.put(key, cand, predicted_s=1.25e-3)
    assert path.exists()
    # a fresh instance (fresh process analogue) reloads from disk
    c2 = TuneCache(path)
    assert c2.get(key) == cand and c2.hits == 1
    # shape bucketing: nearby ragged shape maps to the same key
    p2 = Problem("matmul", 4095, 11007, 4000)
    assert TuneCache.key(p2, backend="pallas", dtype="bfloat16") == key


def test_cache_corrupt_file_degrades_to_empty(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text("{ not json !")
    c = TuneCache(path)
    assert len(c) == 0
    c.put("k", Candidate(128, 128, 128))
    assert TuneCache(path).get("k") == Candidate(128, 128, 128)


def test_autotune_second_call_hits_cache(tmp_cache):
    c1 = tune.best_config("matmul", 512, 512, 512, dtype=jnp.float32,
                          backend="interpret")
    misses = tmp_cache.misses
    c2 = tune.best_config("matmul", 512, 512, 512, dtype=jnp.float32,
                          backend="interpret")
    assert c1 == c2
    assert tmp_cache.hits >= 1 and tmp_cache.misses == misses
    # force=True re-searches but lands on the same deterministic result
    c3 = tune.best_config("matmul", 512, 512, 512, dtype=jnp.float32,
                          backend="interpret", force=True)
    assert c3 == c1


# ----------------------------------------------------------------------
# N-slot revolving-buffer kernels vs the jnp oracle
# ----------------------------------------------------------------------
@pytest.mark.parametrize("slots", [1, 2, 3])
def test_nslot_matmul_matches_ref(rng, slots):
    a = jnp.asarray(rng.standard_normal((32, 40)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((40, 24)), jnp.float32)
    variant = "single" if slots == 1 else "dobu"
    got = zero_stall_matmul(a, b, bm=8, bn=8, bk=8, variant=variant,
                            slots=slots, interpret=True)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("slots", [1, 2, 3])
def test_nslot_matmul_jik_order(rng, slots):
    a = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    variant = "single" if slots == 1 else "dobu"
    got = zero_stall_matmul(a, b, bm=8, bn=8, bk=8, variant=variant,
                            slots=slots, grid_order="jik", interpret=True)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("slots", [1, 2, 3])
def test_nslot_grouped_matmul_matches_ref(rng, slots):
    a = jnp.asarray(rng.standard_normal((3, 16, 24)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((3, 24, 16)), jnp.float32)
    variant = "single" if slots == 1 else "dobu"
    got = grouped_zero_stall_matmul(a, b, bm=8, bn=8, bk=8, variant=variant,
                                    slots=slots, interpret=True)
    np.testing.assert_allclose(got, ref.grouped_matmul_ref(a, b),
                               atol=2e-5, rtol=2e-5)


def test_slots_variant_contradictions_rejected():
    a = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(ValueError):
        zero_stall_matmul(a, a, bm=8, bn=8, bk=8, variant="single",
                          slots=3, interpret=True)
    with pytest.raises(ValueError):
        zero_stall_matmul(a, a, bm=8, bn=8, bk=8, variant="dobu",
                          slots=1, interpret=True)
    with pytest.raises(ValueError):
        zero_stall_matmul(a, a, bm=8, bn=8, bk=8, slots=0, interpret=True)


# ----------------------------------------------------------------------
# Revolving schedule invariant (depth-N Dobu argument)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("slots", [2, 3, 4, 5])
@pytest.mark.parametrize("steps", [1, 2, 3, 7, 32])
def test_revolving_schedule_conflict_free(steps, slots):
    s = RevolvingSchedule(steps=steps, slots=slots)
    assert s.conflict_free()
    assert len(list(s.phases())) == steps
    assert s.prologue_steps() == list(range(min(slots, steps)))


def test_revolving_single_slot_conflicts_by_design():
    assert not RevolvingSchedule(steps=4, slots=1).conflict_free()


def test_revolving_depth2_matches_dobu_slots():
    """slots=2 is the paper's exact scheme — same slot assignment."""
    r = RevolvingSchedule(steps=16, slots=2)
    d = DobuSchedule(steps=16, slots=2)
    assert [r.slot_of(t) for t in range(16)] == \
        [d.slot_of(t) for t in range(16)]


# ----------------------------------------------------------------------
# ops integration: auto-resolving plans
# ----------------------------------------------------------------------
def test_ops_matmul_auto_bit_identical_and_cached(rng, tmp_cache):
    # integer-valued fp32 inputs: every partial sum is exact, so the
    # result is bit-identical regardless of the tiling the tuner picks
    a = jnp.asarray(rng.integers(-4, 5, (33, 47)), jnp.float32)
    b = jnp.asarray(rng.integers(-4, 5, (47, 21)), jnp.float32)
    default = ops.matmul(a, b, config=KernelConfig(backend="interpret"))
    auto = ops.matmul(a, b, config=Plan(backend="interpret"))
    assert tmp_cache.misses >= 1          # tuner actually ran
    np.testing.assert_array_equal(np.asarray(default), np.asarray(auto))
    hits = tmp_cache.hits
    # a FRESH plan re-resolves through the persistent tune cache (a
    # reused Plan would memoize in its own entry table instead)
    auto2 = ops.matmul(a, b, config=Plan(backend="interpret"))
    assert tmp_cache.hits > hits          # second resolution = cache hit
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(auto2))


def test_ops_grouped_matmul_auto(rng, tmp_cache):
    a = jnp.asarray(rng.standard_normal((3, 16, 24)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((3, 24, 16)), jnp.float32)
    got = ops.grouped_matmul(a, b, config=Plan(backend="interpret"))
    np.testing.assert_allclose(got, ref.grouped_matmul_ref(a, b),
                               atol=2e-5, rtol=2e-5)


def test_ops_attention_auto(rng, tmp_cache):
    q = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.float32)
    got = ops.attention(q, q, q, config=Plan(backend="interpret"))
    np.testing.assert_allclose(got, ref.flash_attention_ref(q, q, q),
                               atol=3e-5, rtol=3e-5)


def test_ops_matmul_explicit_tile_config(rng):
    a = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    got = ops.matmul(a, b, config=KernelConfig(backend="interpret",
                                               bm=8, bn=8, bk=8))
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), atol=2e-5)
    with pytest.raises(ValueError):
        ops.matmul(a, b, config="bogus")


def test_ops_matmul_jnp_path_skips_resolution(rng):
    a = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    plan = Plan(backend="jnp")
    np.testing.assert_allclose(
        ops.matmul(a, b, config=plan),
        ref.matmul_ref(a, b), atol=1e-6)
    assert len(plan) == 0     # jnp path never consults the schedule
